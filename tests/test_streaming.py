"""Streaming admission engine tests.

Core guarantee under test: after ANY event sequence, the warm-started
incremental ``solve_streaming`` is numerically equivalent (<= 1e-6, in
practice bit-level) to a cold ``solve_distributed_batch`` of the same final
window — including ragged growth past ``n_max`` and lanes departing
mid-stream — while only dirty lanes iterate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdmissionWindow, CapacityChange, CapacityEngine,
                        ClassArrival, ClassDeparture, CrossCheckPolicy,
                        Policies, RoundingPolicy, SLAEdit, SolverConfig,
                        sample_class_params, sample_event_trace,
                        sample_scenario, solve_centralized,
                        solve_centralized_batch, solve_distributed_batch,
                        replay)


def solve_streaming(window, *, integer=True, mesh=None, cross_check=False):
    """Engine-path stand-in for the retired allocator.solve_streaming facade
    (the shim itself is covered by tests/test_engine.py)."""
    return CapacityEngine(
        SolverConfig(mesh=mesh),
        Policies(rounding=RoundingPolicy(integer),
                 cross_check=CrossCheckPolicy(cross_check))
    ).open_window(window).solve()


def make_window(ns=(5, 8, 3, 6), cf=1.2, n_max=None, seed0=0):
    scns = [sample_scenario(jax.random.PRNGKey(seed0 + i), n,
                            capacity_factor=cf)
            for i, n in enumerate(ns)]
    return AdmissionWindow(scns, n_max=n_max)


def assert_equiv_cold(window, res, tol=1e-6):
    """Streaming result == cold batched re-solve of the same window."""
    cold = solve_distributed_batch(window.batch)
    np.testing.assert_allclose(np.asarray(res.fractional.r),
                               np.asarray(cold.r), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(res.fractional.psi),
                               np.asarray(cold.psi), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(res.fractional.total),
                               np.asarray(cold.total), rtol=tol)
    np.testing.assert_allclose(np.asarray(res.fractional.aux),
                               np.asarray(cold.aux), rtol=tol)
    np.testing.assert_array_equal(np.asarray(res.iters),
                                  np.asarray(cold.iters))
    np.testing.assert_array_equal(np.asarray(res.feasible),
                                  np.asarray(cold.feasible))


# --------------------------------------------------------------------------
# Equivalence with a cold re-solve under event traces
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_streaming_equals_cold_after_random_trace(seed):
    """Event-by-event streaming solves land on the cold equilibrium of every
    intermediate window (the acceptance criterion, three random traces)."""
    window = make_window(n_max=9, seed0=10 * seed)
    solve_streaming(window, integer=False)
    trace = sample_event_trace(100 + seed, window, 30)
    for i, ev in enumerate(trace):
        window.apply(ev)
        res = solve_streaming(window, integer=False)
        if i % 7 == 0 or i == len(trace) - 1:   # spot-check along the way
            assert_equiv_cold(window, res)
    assert_equiv_cold(window, res)


def test_streaming_only_iterates_dirty_lanes():
    window = make_window()
    first = solve_streaming(window, integer=False)
    assert first.resolved.all()                 # first solve is cold
    window.arrive(2, **sample_class_params(jax.random.PRNGKey(7)))
    res = solve_streaming(window, integer=False)
    np.testing.assert_array_equal(res.resolved, [False, False, True, False])
    # frozen lanes carry their stored equilibrium bit-for-bit
    for b in (0, 1, 3):
        np.testing.assert_array_equal(np.asarray(res.fractional.r[b]),
                                      np.asarray(first.fractional.r[b]))
        assert int(res.iters[b]) == int(first.iters[b])
    assert_equiv_cold(window, res)
    # no events since: nothing iterates, result identical
    res2 = solve_streaming(window, integer=False)
    assert not res2.resolved.any()
    np.testing.assert_array_equal(np.asarray(res2.fractional.r),
                                  np.asarray(res.fractional.r))


def test_streaming_growth_past_n_max():
    """Arrival burst grows the padded width; stored equilibria of clean
    lanes stay exact across the repad, and the grown window still matches a
    cold re-solve."""
    window = make_window(ns=(4, 5), n_max=5)
    base = solve_streaming(window, integer=False)
    assert window.n_max == 5
    for i in range(4):                          # lane 1 overflows n_max=5
        window.arrive(1, **sample_class_params(jax.random.PRNGKey(50 + i)))
    assert window.n_max == 10                   # ceil(2.0 * 5)
    res = solve_streaming(window, integer=False)
    np.testing.assert_array_equal(res.resolved, [False, True])
    np.testing.assert_array_equal(np.asarray(res.fractional.r[0][:5]),
                                  np.asarray(base.fractional.r[0]))
    assert np.all(np.asarray(res.fractional.r[0][5:]) == 0.0)
    assert_equiv_cold(window, res)


def test_streaming_departure_and_slot_recycling():
    window = make_window(ns=(3, 6))
    solve_streaming(window, integer=False)
    # depart lane 0 entirely, mid-stream
    for slot in list(window.occupied(0)):
        window.depart(0, slot)
    assert window.n_classes[0] == 0
    res = solve_streaming(window, integer=False)
    assert np.all(np.asarray(res.fractional.r[0]) == 0.0)
    assert bool(res.feasible[0])                # an empty lane is trivially ok
    assert_equiv_cold(window, res)
    # the freed low slots are recycled, lowest first
    assert window.arrive(0, **sample_class_params(jax.random.PRNGKey(3))) == 0
    assert window.arrive(0, **sample_class_params(jax.random.PRNGKey(4))) == 1
    res = solve_streaming(window, integer=False)
    assert_equiv_cold(window, res)


def test_streaming_sla_edit_and_capacity():
    window = make_window(ns=(5, 4))
    solve_streaming(window, integer=False)
    window.apply(SLAEdit(lane=0, slot=2, updates={"E": -600.0, "m": 28000.0}))
    window.apply(CapacityChange(lane=1,
                                R=0.8 * float(window.batch.scenarios.R[1])))
    res = solve_streaming(window, integer=False)
    assert res.resolved.all()
    assert_equiv_cold(window, res)


def test_event_objects_and_replay_determinism():
    w1, w2 = make_window(n_max=8), make_window(n_max=8)
    t1 = sample_event_trace(9, w1, 20)
    t2 = sample_event_trace(9, w2, 20)
    assert t1 == t2                             # replayable: same seed, trace
    replay(w1, t1)
    replay(w2, t2)
    np.testing.assert_array_equal(w1._mask, w2._mask)
    np.testing.assert_allclose(np.asarray(w1.batch.scenarios.A),
                               np.asarray(w2.batch.scenarios.A), rtol=0)
    kinds = {type(e) for e in t1}
    assert ClassArrival in kinds and ClassDeparture in kinds


def test_window_validation_errors():
    window = make_window(ns=(3,))
    with pytest.raises(IndexError):
        window.depart(0, 5)                     # padded slot holds no class
    with pytest.raises(IndexError):
        window.arrive(4, **sample_class_params(jax.random.PRNGKey(0)))
    with pytest.raises(ValueError):
        window.edit(0, 0, not_a_field=1.0)
    with pytest.raises(ValueError):
        window.arrive(0, A=1.0)                 # missing raw fields


# --------------------------------------------------------------------------
# Centralized baseline: masked + batched water-filling cross-check
# --------------------------------------------------------------------------

def test_centralized_batch_matches_per_instance():
    window = make_window(ns=(5, 8, 3), cf=0.95)
    batch = window.batch
    cb = solve_centralized_batch(batch)
    for b in range(batch.batch_size):
        single = solve_centralized(batch.instance(b))
        n = int(batch.n_classes[b])
        np.testing.assert_allclose(np.asarray(cb.r[b][:n]),
                                   np.asarray(single.r), rtol=1e-9)
        assert float(cb.total[b]) == pytest.approx(float(single.total),
                                                   rel=1e-9)
    # padded classes are inert
    assert np.all(np.asarray(cb.r)[~np.asarray(batch.mask)] == 0.0)


def test_streaming_cross_check_gap_nonnegative():
    window = make_window(cf=0.95)
    res = solve_streaming(window, integer=False, cross_check=True)
    assert res.centralized_gap is not None
    # GNEP equilibrium can never beat the exact (P3) optimum
    assert np.all(np.asarray(res.centralized_gap) >= -1e-9)
    assert not window.baseline_stale.any()      # memoized after the check
    # events invalidate only the touched lanes' baselines ...
    window.arrive(1, **sample_class_params(jax.random.PRNGKey(11)))
    window.depart(0, window.occupied(0)[-1])
    np.testing.assert_array_equal(window.baseline_stale,
                                  [True, True, False, False])
    frozen_baselines = window.baseline_totals[2:].copy()
    res = solve_streaming(window, integer=False, cross_check=True)
    assert np.all(np.asarray(res.centralized_gap) >= -1e-9)
    # ... and untouched lanes' memoized baselines are served unchanged
    np.testing.assert_array_equal(window.baseline_totals[2:],
                                  frozen_baselines)
    # the memoized gaps equal a from-scratch batched baseline
    cold_cent = solve_centralized_batch(window.batch)
    np.testing.assert_allclose(
        np.asarray(res.centralized_gap),
        np.asarray((res.fractional.total - cold_cent.total)
                   / jnp.maximum(jnp.abs(cold_cent.total), 1.0)),
        rtol=1e-9, atol=1e-12)


# --------------------------------------------------------------------------
# Rounding + fleet integration
# --------------------------------------------------------------------------

def test_streaming_integer_rounding_consistent():
    window = make_window(cf=0.95)
    window.arrive(2, **sample_class_params(jax.random.PRNGKey(5)))
    res = solve_streaming(window)
    mask = np.asarray(window.batch.mask)
    for x in (res.integer.r, res.integer.sM, res.integer.sR, res.integer.h):
        x = np.asarray(x)
        np.testing.assert_array_equal(x, np.round(x))
        assert np.all(x[~mask] == 0.0)
    R = np.asarray(window.batch.scenarios.R)
    assert np.all(np.asarray(res.integer.r).sum(axis=1)
                  <= np.floor(R) + 1e-9)


def test_fleet_epoch_stream_matches_fresh_epoch():
    """Streaming fleet epochs land on the same allocation a from-scratch
    single-fleet epoch computes for the post-event tenant mix."""
    from repro.cluster import FleetSimulator, TenantSpec, epoch_stream

    def tenants(k, start=0):
        return [TenantSpec(f"t{start + i}", "x", "train_4k",
                           deadline_s=100.0 + 7.0 * (start + i),
                           H_up=10 + (start + i), H_low=4,
                           penalty_per_job=20000.0 + 500.0 * (start + i))
                for i in range(k)]

    profiles = {f"t{i}": (1.0 + 0.2 * i, 0.5, 1.0) for i in range(8)}
    mk = lambda chips, k: FleetSimulator(total_chips=chips,
                                         tenants=tenants(k))
    streamed = [mk(800, 2), mk(1200, 4)]
    for f in streamed:
        f._profiles = dict(profiles)

    newcomer = tenants(1, start=5)[0]
    epochs = [
        [],                                      # epoch 0: initial mix
        [("arrive", 0, newcomer), ("depart", 1, "t1")],
        [("edit", 0, "t0", {"deadline_s": 80.0}), ("capacity", 1, 1100)],
    ]
    got = list(epoch_stream(streamed, epochs))
    assert len(got) == 3 and all(len(a) == 2 for a in got)
    assert all(a.feasible for epoch in got for a in epoch)

    # replay each end state on fresh fleets solved the plain (cold) way
    fresh0 = mk(800, 2)
    fresh0.tenants.append(newcomer)
    fresh0.tenants[0].deadline_s = 80.0
    fresh1 = mk(1100, 4)
    fresh1.tenants = [t for t in fresh1.tenants if t.name != "t1"]
    for f in (fresh0, fresh1):
        f._profiles = dict(profiles)
    want0, want1 = fresh0.epoch(), fresh1.epoch()

    assert got[-1][0].chips == want0.chips
    assert got[-1][0].h == want0.h
    assert got[-1][1].chips == want1.chips
    assert got[-1][1].h == want1.h
    assert got[-1][0].total_cost == pytest.approx(want0.total_cost, rel=1e-6)
    assert got[-1][1].total_cost == pytest.approx(want1.total_cost, rel=1e-6)
    # streaming appended one Allocation per epoch to each fleet's history
    assert [len(f.history) for f in streamed] == [3, 3]

    # a duplicate tenant name would silently desync slots <-> window: guard
    dup = tenants(1)[0]                       # "t0" already exists in fleet 0
    with pytest.raises(ValueError, match="already has a tenant"):
        list(epoch_stream(streamed, [[("arrive", 0, dup)]]))
