"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import reference as attn_ref
from repro.kernels.gnep_sweep.kernel import rm_sweep
from repro.kernels.gnep_sweep.ref import reference as sweep_ref
from repro.kernels.rwkv6.kernel import wkv6
from repro.kernels.rwkv6.ref import reference as wkv_ref

from _tolerance import assert_ulp_close

KEY = jax.random.PRNGKey(0)


# ----------------------------- flash attention -----------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("B,S,Hq,Hkv,hd,causal,bq,bk", [
    (2, 256, 4, 2, 64, True, 64, 64),
    (1, 128, 8, 8, 32, False, 64, 32),
    (2, 192, 6, 3, 64, True, 64, 64),     # uneven grid (192/64=3)
    (1, 256, 4, 1, 128, True, 128, 64),   # MQA, hd=128
])
def test_flash_attention_sweep(dtype, tol, B, S, Hq, Hkv, hd, causal, bq, bk):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    ref = attn_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


# ----------------------------------- wkv6 ----------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-4),
                                       (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("B,T,H,K,chunk", [
    (2, 128, 3, 16, 32), (1, 256, 2, 64, 64), (2, 64, 4, 8, 16),
])
def test_wkv6_sweep(dtype, tol, B, T, H, K, chunk):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, T, H, K), dtype)
    k = jax.random.normal(ks[1], (B, T, H, K), dtype)
    v = jax.random.normal(ks[2], (B, T, H, K), dtype)
    w_log = -jnp.exp(jax.random.normal(ks[3], (B, T, H, K),
                                       jnp.float32) * 0.5 - 0.6)
    u = (jax.random.normal(ks[4], (H, K), jnp.float32) * 0.3)
    y, S = wkv6(r, k, v, w_log.astype(dtype), u, chunk=chunk, interpret=True)
    y_ref, S_ref = wkv_ref(r.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32), w_log, u,
                           jnp.zeros((B, H, K, K)))
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=tol, atol=tol * 10)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref, np.float32),
                               rtol=tol, atol=tol * 10)


# -------------------------------- gnep sweep --------------------------------

@pytest.mark.parametrize("Nc,N,bc,bn", [
    (64, 256, 32, 64), (100, 333, 32, 128), (8, 1024, 8, 256),
])
def test_gnep_sweep(Nc, N, bc, bn):
    ks = jax.random.split(KEY, 2)
    inc = jax.random.uniform(ks[0], (Nc, N), jnp.float32, 0.0, 10.0)
    # random mask mimicking the y-pattern
    inc = inc * (jax.random.uniform(ks[1], (Nc, N)) > 0.4)
    p = jnp.sort(jax.random.uniform(ks[1], (N,), jnp.float32, 0.1, 100.0)
                 )[::-1]
    spare = 0.3 * float(inc.sum() / Nc)
    fill, sf, pf = rm_sweep(inc, spare, p, block_c=bc, block_n=bn,
                            interpret=True)
    fill_r, sf_r, pf_r = sweep_ref(inc, spare, p)
    # Kernel and reference are both f32 but sum the prefix in different
    # orders (blockwise carry vs one cumsum); near the clip boundary the
    # fill difference is O(ulp(sum(inc))), so the tolerance is ULPs at the
    # running-sum magnitude — for pf, at the p-weighted sum's magnitude.
    assert_ulp_close(fill, fill_r, ulps=8,
                     scale=jnp.sum(inc, axis=1), rtol=1e-5, err_msg="fill")
    assert_ulp_close(sf, sf_r, ulps=8,
                     scale=jnp.sum(inc, axis=1), rtol=1e-5, err_msg="sum_fill")
    assert_ulp_close(pf, pf_r, ulps=8,
                     scale=jnp.sum(inc * p[None, :], axis=1), rtol=1e-5,
                     err_msg="p_fill")


def test_gnep_sweep_plugs_into_rm_solve():
    """rm_solve(sweep_fn=pallas) == rm_solve(default) on a real scenario."""
    from repro.core import sample_scenario
    from repro.core.game import rm_solve
    from repro.kernels.gnep_sweep.ops import make_sweep_fn

    scn = sample_scenario(jax.random.PRNGKey(3), 64, capacity_factor=0.9)
    bids = jax.random.uniform(jax.random.PRNGKey(4), (64,),
                              scn.A.dtype, float(scn.rho_bar), 20.0)
    rho0, r0, obj0 = rm_solve(scn, bids)
    fn = make_sweep_fn(force_pallas=True)

    def sweep32(inc, spare, p):
        f, s, pv = fn(inc.astype(jnp.float32), spare, p)
        return f.astype(inc.dtype), s.astype(inc.dtype), pv.astype(inc.dtype)

    rho1, r1, obj1 = rm_solve(scn, bids, sweep_fn=sweep32)
    assert float(rho0) == pytest.approx(float(rho1), rel=1e-5)
    np.testing.assert_allclose(np.asarray(r0), np.asarray(r1),
                               rtol=1e-4, atol=1e-2)
