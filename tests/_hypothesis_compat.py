"""Shared hypothesis import shim: real property-based testing when the
package is installed (CI installs it), a LOUD per-test skip when not.

The PR 1 fallback silently ran each ``@given`` test on one deterministic
midpoint example, which let the suite stay green while property coverage
quietly degraded to a point check.  Now every ``@given`` test skips with
an explicit reason when hypothesis is absent, so the hole shows up in the
pytest summary instead of hiding inside a pass count.

Usage (replaces ``from hypothesis import given, settings, strategies``):

    from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _St:
        """Stand-in strategy namespace: any strategy constructor returns an
        inert placeholder — the ``given`` fallback never draws from it."""

        def __getattr__(self, name):
            return lambda *a, **kw: None

    st = _St()

    def settings(*_a, **_kw):
        return lambda f: f

    def given(*_a, **_kw):
        def deco(f):
            def wrapper():   # zero-arg: params must not look like fixtures
                pytest.skip("hypothesis not installed: property-based "
                            f"search for {f.__name__} skipped")
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper
        return deco
