"""Device-sharded lane-parallel solver tests.

Core guarantee under test: ``solve_distributed_batch(mesh=...)`` /
``solve_streaming(mesh=...)`` over the forced host devices
(``conftest.py`` sets ``--xla_force_host_platform_device_count=8``) match
the unsharded solvers to <= 1e-6 (in practice bit-equal) — including ragged
class counts, lane counts not divisible by the device count, streaming
dirty-lane re-solves and warm-start parity.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdmissionWindow, CapacityEngine, Policies,
                        RoundingPolicy, SolverConfig, lane_mesh,
                        pad_batch_lanes, pad_warm_start, padded_lane_count,
                        sample_class_params, sample_event_trace,
                        sample_scenario, solve_distributed_batch,
                        stack_scenarios)
from repro.core.game import cold_start


def solve_batch(batch, *, mesh=None):
    """Engine-path stand-in for the retired allocator.solve_batch facade."""
    return CapacityEngine(SolverConfig(mesh=mesh)).solve(batch)


def solve_streaming(window, *, integer=True, mesh=None):
    """Engine-path stand-in for the retired allocator.solve_streaming
    facade (shims themselves are covered by tests/test_engine.py)."""
    return CapacityEngine(
        SolverConfig(mesh=mesh),
        Policies(rounding=RoundingPolicy(integer))
    ).open_window(window).solve()

D = jax.device_count()
needs_devices = pytest.mark.skipif(
    D < 2, reason="needs >= 2 devices (conftest forces 8 on CPU)")

# deliberately NOT divisible by 8 (or 4, or 2): exercises inert-lane padding
RAGGED_NS = [5, 17, 9, 12, 3, 26, 7, 31, 11, 4, 8]


def make_batch(ns=RAGGED_NS, cf=0.95, seed0=0):
    scns = [sample_scenario(jax.random.PRNGKey(seed0 + i), n,
                            capacity_factor=cf)
            for i, n in enumerate(ns)]
    return scns, stack_scenarios(scns)


def assert_solution_equiv(sharded, ref, tol=1e-6):
    np.testing.assert_allclose(np.asarray(sharded.r), np.asarray(ref.r),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(sharded.psi), np.asarray(ref.psi),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(sharded.total),
                               np.asarray(ref.total), rtol=tol)
    np.testing.assert_allclose(np.asarray(sharded.aux), np.asarray(ref.aux),
                               rtol=tol)
    np.testing.assert_array_equal(np.asarray(sharded.iters),
                                  np.asarray(ref.iters))
    np.testing.assert_array_equal(np.asarray(sharded.feasible),
                                  np.asarray(ref.feasible))


# --------------------------------------------------------------------------
# Lane padding helpers
# --------------------------------------------------------------------------

def test_padded_lane_count():
    assert padded_lane_count(11, 8) == 16
    assert padded_lane_count(16, 8) == 16
    assert padded_lane_count(1, 8) == 8
    assert padded_lane_count(9, 1) == 9
    with pytest.raises(ValueError):
        padded_lane_count(0, 8)


def test_pad_batch_lanes_inert():
    _, batch = make_batch()
    padded = pad_batch_lanes(batch, 16)
    assert padded.batch_size == 16 and padded.n_max == batch.n_max
    # real lanes untouched, pad lanes fully masked off and trivially feasible
    np.testing.assert_array_equal(np.asarray(padded.mask[:11]),
                                  np.asarray(batch.mask))
    assert not np.asarray(padded.mask[11:]).any()
    assert np.all(np.asarray(padded.n_classes[11:]) == 0)
    # solving the padded batch leaves real lanes' results unchanged and the
    # pad lanes converge immediately to the empty allocation
    ref = solve_distributed_batch(batch)
    sol = solve_distributed_batch(padded)
    np.testing.assert_array_equal(np.asarray(sol.r[:11]), np.asarray(ref.r))
    np.testing.assert_array_equal(np.asarray(sol.iters[:11]),
                                  np.asarray(ref.iters))
    assert np.all(np.asarray(sol.r[11:]) == 0.0)
    assert np.asarray(sol.feasible[11:]).all()
    # identity fast path + guard
    assert pad_batch_lanes(batch, batch.batch_size) is batch
    with pytest.raises(ValueError):
        pad_batch_lanes(batch, batch.batch_size - 1)


def test_pad_warm_start_frozen():
    _, batch = make_batch(ns=[4, 7, 5])
    init = cold_start(batch)
    padded = pad_warm_start(init, 8)
    assert padded.active.shape == (8,)
    assert np.asarray(padded.active[:3]).all()
    assert not np.asarray(padded.active[3:]).any()      # pad lanes frozen
    assert np.all(np.asarray(padded.r[3:]) == 0.0)
    assert pad_warm_start(init, 3) is init


def test_lane_mesh_validation():
    with pytest.raises(ValueError):
        lane_mesh(0)
    with pytest.raises(ValueError):
        lane_mesh(D + 1)
    mesh = lane_mesh()
    assert mesh.devices.size == D and mesh.axis_names == ("lanes",)


# --------------------------------------------------------------------------
# Sharded == unsharded: batched solves
# --------------------------------------------------------------------------

@needs_devices
def test_sharded_matches_unsharded_ragged():
    """Ragged class counts AND a lane count (11) not divisible by the
    device count: every lane's trajectory matches the unsharded solver."""
    _, batch = make_batch()
    ref = solve_distributed_batch(batch)
    sol = solve_distributed_batch(batch, mesh=lane_mesh())
    assert sol.r.shape == ref.r.shape                   # padding trimmed
    assert_solution_equiv(sol, ref)


@needs_devices
@pytest.mark.parametrize("n_dev", sorted({2, D}))
def test_sharded_device_counts_agree(n_dev):
    """The result is independent of the mesh size (1 device == 2 == D)."""
    _, batch = make_batch(ns=[6, 13, 4, 9, 21])
    ref = solve_distributed_batch(batch, mesh=lane_mesh(1))
    sol = solve_distributed_batch(batch, mesh=lane_mesh(n_dev))
    assert_solution_equiv(sol, ref)
    assert_solution_equiv(ref, solve_distributed_batch(batch))


@needs_devices
def test_sharded_divisible_lane_count():
    """B an exact multiple of the device count: no padding path."""
    _, batch = make_batch(ns=[5, 9, 13, 7] * (2 * D // 4 if D >= 4 else 2))
    assert batch.batch_size % D == 0 or D < 4
    ref = solve_distributed_batch(batch)
    sol = solve_distributed_batch(batch, mesh=lane_mesh())
    assert_solution_equiv(sol, ref)


@needs_devices
def test_solve_batch_facade_with_mesh():
    """Engine batch solve with SolverConfig(mesh=...): identical integer
    allocations to the unsharded engine path."""
    scns, batch = make_batch(ns=[5, 17, 9, 12, 3])
    ref = solve_batch(batch)
    res = solve_batch(batch, mesh=lane_mesh())
    np.testing.assert_array_equal(np.asarray(res.integer.r),
                                  np.asarray(ref.integer.r))
    np.testing.assert_array_equal(np.asarray(res.integer.h),
                                  np.asarray(ref.integer.h))
    np.testing.assert_allclose(np.asarray(res.total), np.asarray(ref.total),
                               rtol=1e-9)
    np.testing.assert_array_equal(np.asarray(res.iters),
                                  np.asarray(ref.iters))


@needs_devices
def test_sharded_warm_start_parity():
    """A mixed frozen/active BatchWarmStart shards faithfully: frozen lanes
    pass their stored equilibrium through untouched, active lanes iterate the
    cold trajectory — exactly as unsharded."""
    _, batch = make_batch(ns=[6, 11, 4, 9, 14, 3])
    base = solve_distributed_batch(batch)
    cold = cold_start(batch)
    frozen = jnp.asarray([True, False, True, False, False, True])
    init = cold._replace(
        r=jnp.where(frozen[:, None], base.r, cold.r),
        rho=jnp.where(frozen, base.aux, cold.rho),
        lane_iters=jnp.where(frozen, base.iters.astype(jnp.int32),
                             cold.lane_iters),
        active=~frozen)
    ref = solve_distributed_batch(batch, init=init)
    sol = solve_distributed_batch(batch, init=init, mesh=lane_mesh())
    assert_solution_equiv(sol, ref)
    # frozen lanes really were pass-through in both paths
    for b in (0, 2, 5):
        np.testing.assert_array_equal(np.asarray(sol.r[b]),
                                      np.asarray(base.r[b]))
        assert int(sol.iters[b]) == int(base.iters[b])


# --------------------------------------------------------------------------
# Sharded == unsharded: streaming dirty-lane re-solves
# --------------------------------------------------------------------------

def make_window(ns=(5, 8, 3, 6, 4), cf=1.2, n_max=None, seed0=0):
    scns = [sample_scenario(jax.random.PRNGKey(seed0 + i), n,
                            capacity_factor=cf)
            for i, n in enumerate(ns)]
    return AdmissionWindow(scns, n_max=n_max)


@needs_devices
def test_streaming_dirty_lane_resolve_under_mesh():
    """Only the dirtied lane iterates, and the sharded streaming result
    equals both the unsharded streaming result and a cold re-solve."""
    mesh = lane_mesh()
    w_mesh, w_ref = make_window(), make_window()
    first_m = solve_streaming(w_mesh, integer=False, mesh=mesh)
    first_r = solve_streaming(w_ref, integer=False)
    assert first_m.resolved.all()
    assert_solution_equiv(first_m.fractional, first_r.fractional)

    params = sample_class_params(jax.random.PRNGKey(7))
    w_mesh.arrive(2, **params)
    w_ref.arrive(2, **params)
    res_m = solve_streaming(w_mesh, integer=False, mesh=mesh)
    res_r = solve_streaming(w_ref, integer=False)
    np.testing.assert_array_equal(res_m.resolved,
                                  [False, False, True, False, False])
    assert_solution_equiv(res_m.fractional, res_r.fractional)
    # frozen lanes carried their stored equilibrium across the shard trip
    for b in (0, 1, 3, 4):
        np.testing.assert_array_equal(np.asarray(res_m.fractional.r[b]),
                                      np.asarray(first_m.fractional.r[b]))
    cold = solve_distributed_batch(w_mesh.batch)
    assert_solution_equiv(res_m.fractional, cold)


@needs_devices
def test_streaming_random_trace_under_mesh():
    """Event-by-event sharded streaming lands on the unsharded equilibria
    throughout a random trace (arrivals, departures, edits, capacity)."""
    mesh = lane_mesh()
    w_mesh, w_ref = make_window(n_max=9), make_window(n_max=9)
    solve_streaming(w_mesh, integer=False, mesh=mesh)
    solve_streaming(w_ref, integer=False)
    trace = sample_event_trace(42, w_mesh, 25)
    for i, ev in enumerate(trace):
        w_mesh.apply(ev)
        w_ref.apply(ev)
        res_m = solve_streaming(w_mesh, integer=False, mesh=mesh)
        if i % 5 == 0 or i == len(trace) - 1:
            res_r = solve_streaming(w_ref, integer=False)
            np.testing.assert_array_equal(res_m.resolved, res_r.resolved)
            assert_solution_equiv(res_m.fractional, res_r.fractional)
        else:
            solve_streaming(w_ref, integer=False)
    assert_solution_equiv(res_m.fractional,
                          solve_distributed_batch(w_mesh.batch))


# --------------------------------------------------------------------------
# Fleet integration
# --------------------------------------------------------------------------

@needs_devices
def test_fleet_epoch_batch_with_mesh():
    from repro.cluster import FleetSimulator, TenantSpec, epoch_batch

    def tenants(k):
        return [TenantSpec(f"t{i}", "x", "train_4k", deadline_s=100.0,
                           H_up=10 + i, H_low=4, penalty_per_job=20000.0)
                for i in range(k)]

    profiles = {f"t{i}": (1.0 + 0.2 * i, 0.5, 1.0) for i in range(4)}
    mk = lambda chips, k: FleetSimulator(total_chips=chips,
                                         tenants=tenants(k))
    plain = [mk(800, 2), mk(1200, 4), mk(600, 3)]
    meshed = [mk(800, 2), mk(1200, 4), mk(600, 3)]
    for f in plain + meshed:
        f._profiles = profiles
    want = epoch_batch(plain)
    got = epoch_batch(meshed, mesh=lane_mesh())
    for g, w in zip(got, want):
        assert g.chips == w.chips and g.h == w.h
        assert g.total_cost == pytest.approx(w.total_cost, rel=1e-9)
