"""Shared ULP-scaled tolerance helpers for kernel differential tests.

PR 1 left a hand-rolled scale-aware tolerance inside
``test_kernels.py::test_gnep_sweep``: a kernel and its reference that sum
the same prefix in different orders disagree by O(ulp(running_sum)), so a
fixed ``atol`` either flakes on large sums or hides real bugs on small
ones.  That reasoning is general — every differential harness comparing
two reduction orders needs it — so it lives here now, phrased in ULPs:

* :func:`ulp_at` — the size of one unit-in-the-last-place at a given
  magnitude, the only machine-independent currency for rounding error;
* :func:`reduction_ulp_atol` — the absolute tolerance for comparing two
  different-order reductions of the same summands;
* :func:`assert_ulp_close` — ``assert_allclose`` with the ``atol``
  derived from ULPs at an explicit scale instead of guessed constants;
* :func:`assert_bitwise_equal` — the *other* side of the contract: where
  two formulations accumulate in the SAME order (the fused gnep_iter
  kernel vs its scan reference), the right tolerance is none at all, and
  a bytes-level compare says so unambiguously (it also distinguishes
  ``-0.0`` from ``0.0`` and NaN payloads, which ``==`` cannot).

Used by ``test_kernels.py`` (gnep_sweep) and ``test_fused_iter.py``.
"""
import numpy as np


def ulp_at(x, dtype=None):
    """One ULP of ``dtype`` at the magnitude of ``x`` (a python float).

    ``x`` may be an array — its largest \\|value\\| sets the magnitude.  A
    zero/empty magnitude falls back to the dtype's smallest positive
    normal so the result is never 0 (a zero tolerance by accident is a
    bug magnet).

    Parameters
    ----------
    x : array_like
        Value(s) whose magnitude anchors the ULP.
    dtype : numpy dtype, optional
        Float type whose precision to use; defaults to ``x``'s dtype.
    """
    arr = np.asarray(x)
    info = np.finfo(np.dtype(dtype) if dtype is not None else arr.dtype)
    mag = float(np.max(np.abs(arr))) if arr.size else 0.0
    return max(mag, float(info.tiny)) * float(info.eps)


def reduction_ulp_atol(summands, axis, *, ulps=4, dtype=None):
    """Absolute tolerance for two different-order reductions of ``summands``.

    Reducing the same terms blockwise-with-carry vs one ``cumsum`` (the
    gnep kernels' situation) perturbs each partial sum by a few ULPs *of
    the running-sum magnitude*, not of the individual terms; downstream
    clips/min-maxes preserve that scale.  This returns ``ulps`` ULPs at
    the largest reduction magnitude along ``axis``.

    Parameters
    ----------
    summands : array_like
        The terms being reduced (e.g. the fill increments).
    axis : int or tuple
        Reduction axis/axes of the compared computation.
    ulps : int, optional
        Error budget in ULPs (default 4: a handful of reorderings).
    dtype : numpy dtype, optional
        Precision of the compared computation; defaults to the summands'.
    """
    arr = np.asarray(summands)
    sums = np.sum(np.abs(arr.astype(np.float64)), axis=axis)
    return ulps * ulp_at(sums, dtype if dtype is not None else arr.dtype)


def assert_ulp_close(actual, desired, *, ulps=4, scale=None, rtol=0.0,
                     err_msg=""):
    """``assert_allclose`` with an ULP-derived absolute tolerance.

    Parameters
    ----------
    actual, desired : array_like
        The two results to compare.
    ulps : int, optional
        Error budget in ULPs (default 4).
    scale : array_like, optional
        Magnitude anchor for the ULP; defaults to ``desired`` itself.
        Pass the running-sum array when comparing reduction outputs whose
        elements are much smaller than the sums that produced them.
    rtol : float, optional
        Extra elementwise relative term, forwarded to ``assert_allclose``.
    err_msg : str, optional
        Failure-message prefix, forwarded to ``assert_allclose``.
    """
    d = np.asarray(desired)
    anchor = d if scale is None else scale
    np.testing.assert_allclose(
        np.asarray(actual), d, rtol=rtol,
        atol=ulps * ulp_at(anchor, d.dtype), err_msg=err_msg)


def assert_bitwise_equal(actual, desired, label=""):
    """Assert two arrays are identical down to the last bit.

    Shape, dtype and the raw bytes must all match — the assertion a
    *same-accumulation-order* differential contract demands (gnep_iter
    kernel vs its scan reference).  On mismatch the message reports the
    worst absolute deviation and the count of differing elements, which
    is what one actually wants to know when bit-equality breaks.

    Parameters
    ----------
    actual, desired : array_like
        The two results to compare.
    label : str, optional
        Name of the compared quantity for the failure message.
    """
    a, d = np.asarray(actual), np.asarray(desired)
    tag = f"{label}: " if label else ""
    assert a.shape == d.shape, f"{tag}shape {a.shape} != {d.shape}"
    assert a.dtype == d.dtype, f"{tag}dtype {a.dtype} != {d.dtype}"
    if a.tobytes() == d.tobytes():
        return
    if np.issubdtype(a.dtype, np.floating):
        neq = a.view(np.uint8) != d.view(np.uint8)
        dev = float(np.max(np.abs(np.nan_to_num(a - d))))
        raise AssertionError(
            f"{tag}not bit-equal: {int(np.count_nonzero(neq))} differing "
            f"byte(s), max abs deviation {dev:.3e}")
    raise AssertionError(f"{tag}not bit-equal")
